//! Source-scan lint engine behind `cargo xtask verify`.
//!
//! The paper's guarantees (bit-identical parallel GEMM, fused ==
//! materialized conv, bit-identical crash resume) are *algorithmic*
//! invariants: RegTop-k's posterior statistics are functions of exact past
//! aggregates, so nondeterminism or unsoundness silently corrupts the
//! algorithm rather than just the numbers. The example-based parity tests
//! catch regressions after the fact; these lints fail the build the moment
//! a PR introduces a pattern that *could* break an invariant:
//!
//! | rule | invariant protected |
//! |------|---------------------|
//! | `safety-comment` | every `unsafe` site carries its precondition (`// SAFETY:` or a `# Safety` doc section) |
//! | `float-ord-unwrap` | no `partial_cmp(..).unwrap()` on floats outside `sparsify/select.rs`'s NaN total order — the PR 1 panic class |
//! | `determinism` | no ambient RNG inside the deterministic paths (`sparsify/`, `coordinator/`, `tensor/`) |
//! | `time-funnel` | every wall-clock read goes through `obs::clock` — timestamps are observability *outputs* only, so tracing cannot perturb training |
//! | `log-choke` | stderr diagnostics go through `obs::log` (leveled, capturable) — no ad-hoc `eprintln` that tests can't observe |
//! | `thread-spawn` | all OS-thread creation funnels through `tensor::pool` (thread-budget discipline) |
//!
//! The scanner is deliberately dependency-free: it masks comments and
//! string/char literals with a small lexer state machine, then matches
//! word-bounded tokens against the masked code, so `"thread::spawn"` in a
//! string or a doc comment never trips a rule. It is a lint, not a parser
//! — precise enough for these six patterns, and every rule ships with a
//! seeded negative test below proving it still fires.

use std::fmt;
use std::path::{Path, PathBuf};

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Stable rule identifier (used by CI annotations and the README table).
    pub rule: &'static str,
    /// Path relative to the repo root.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// How many lines above an `unsafe` token a `SAFETY:` comment may sit
/// (attributes and the fn signature commonly separate them).
const SAFETY_WINDOW: usize = 10;

/// The one module allowed to order floats with `partial_cmp(..).unwrap()`
/// — it implements the crate's blessed NaN-last total order.
const FLOAT_ORD_HOME: &str = "rust/src/sparsify/select.rs";

/// The one module allowed to create OS threads.
const THREAD_HOME: &str = "rust/src/tensor/pool.rs";

/// Deterministic-path prefixes for the RNG rule: everything the
/// bit-identity guarantees flow through.
const DETERMINISTIC_DIRS: [&str; 3] =
    ["rust/src/sparsify/", "rust/src/coordinator/", "rust/src/tensor/"];

/// Ambient-RNG tokens banned inside [`DETERMINISTIC_DIRS`].
const RNG_TOKENS: [&str; 3] = ["thread_rng", "from_entropy", "rand::random"];

/// Wall-clock tokens banned crate-wide (outside [`TIME_HOME`]): the
/// flight recorder's zero-perturbation guarantee needs every timestamp to
/// flow through one auditable choke point, not just the deterministic
/// dirs — a stray `Instant::now` in the bench or experiment layer is how
/// timing sneaks back into control flow.
const TIME_TOKENS: [&str; 3] = ["Instant::now", "SystemTime::now", "UNIX_EPOCH"];

/// The one module allowed to read the wall clock (`obs::clock` — epoch,
/// `now_ns`, `Stopwatch`).
const TIME_HOME: &str = "rust/src/obs/clock.rs";

/// Modules allowed to write to stderr directly: the leveled log sink
/// itself, and the CLI entry point's usage/error reporting.
const LOG_HOMES: [&str; 2] = ["rust/src/obs/log.rs", "rust/src/main.rs"];

/// Masked views of one source file: `code` keeps code bytes and blanks
/// comments + string/char-literal contents; `comments` keeps comment text
/// and blanks everything else. Both are byte-for-byte the same length as
/// the input with newlines preserved, so line numbers line up across all
/// three.
struct Masked {
    code: String,
    comments: String,
}

fn mask(src: &str) -> Masked {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(usize),
        Str,
        RawStr(usize),
        CharLit,
    }
    let bytes = src.as_bytes();
    let mut code = Vec::with_capacity(bytes.len());
    let mut comments = Vec::with_capacity(bytes.len());
    let mut st = St::Code;
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        // Newlines always pass through both views.
        if b == b'\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            code.push(b'\n');
            comments.push(b'\n');
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
                    // Push only this '/'; the second one is handled (and
                    // pushed) in LineComment state next iteration.
                    st = St::LineComment;
                    code.push(b' ');
                    comments.push(b'/');
                } else if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    st = St::BlockComment(1);
                    code.push(b' ');
                    code.push(b' ');
                    comments.push(b'/');
                    comments.push(b'*');
                    i += 2;
                    continue;
                } else if b == b'"' {
                    st = St::Str;
                    code.push(b'"');
                    comments.push(b' ');
                } else if b == b'r'
                    && (i == 0 || !is_ident_byte(bytes[i - 1]))
                    && raw_str_hashes(bytes, i + 1).is_some()
                {
                    let h = raw_str_hashes(bytes, i + 1).unwrap();
                    // r, the hashes, and the opening quote
                    for _ in 0..h + 2 {
                        code.push(b' ');
                        comments.push(b' ');
                    }
                    st = St::RawStr(h);
                    i += h + 2;
                    continue;
                } else if b == b'b' && bytes.get(i + 1) == Some(&b'"') {
                    code.push(b' ');
                    code.push(b'"');
                    comments.push(b' ');
                    comments.push(b' ');
                    st = St::Str;
                    i += 2;
                    continue;
                } else if b == b'\'' {
                    // Char literal vs lifetime: a literal closes within a
                    // couple of characters ('x', '\n', '\u{..}'); a
                    // lifetime ('a, 'static, '_) never closes.
                    if is_char_literal(bytes, i) {
                        st = St::CharLit;
                        code.push(b'\'');
                        comments.push(b' ');
                    } else {
                        code.push(b);
                        comments.push(b' ');
                    }
                } else {
                    code.push(b);
                    comments.push(b' ');
                }
            }
            St::LineComment => {
                code.push(b' ');
                comments.push(b);
            }
            St::BlockComment(depth) => {
                if b == b'*' && bytes.get(i + 1) == Some(&b'/') {
                    code.push(b' ');
                    code.push(b' ');
                    comments.push(b'*');
                    comments.push(b'/');
                    st = if depth == 1 { St::Code } else { St::BlockComment(depth - 1) };
                    i += 2;
                    continue;
                } else if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    code.push(b' ');
                    code.push(b' ');
                    comments.push(b'/');
                    comments.push(b'*');
                    st = St::BlockComment(depth + 1);
                    i += 2;
                    continue;
                } else {
                    code.push(b' ');
                    comments.push(b);
                }
            }
            St::Str => {
                if b == b'\\' && i + 1 < bytes.len() {
                    code.push(b' ');
                    code.push(b' ');
                    comments.push(b' ');
                    comments.push(b' ');
                    i += 2;
                    continue;
                } else if b == b'"' {
                    code.push(b'"');
                    comments.push(b' ');
                    st = St::Code;
                } else {
                    code.push(b' ');
                    comments.push(b' ');
                }
            }
            St::RawStr(h) => {
                if b == b'"' && bytes[i + 1..].iter().take_while(|&&c| c == b'#').count() >= h {
                    for _ in 0..h + 1 {
                        code.push(b' ');
                        comments.push(b' ');
                    }
                    st = St::Code;
                    i += h + 1;
                    continue;
                } else {
                    code.push(b' ');
                    comments.push(b' ');
                }
            }
            St::CharLit => {
                if b == b'\\' && i + 1 < bytes.len() {
                    code.push(b' ');
                    code.push(b' ');
                    comments.push(b' ');
                    comments.push(b' ');
                    i += 2;
                    continue;
                } else if b == b'\'' {
                    code.push(b'\'');
                    comments.push(b' ');
                    st = St::Code;
                } else {
                    code.push(b' ');
                    comments.push(b' ');
                }
            }
        }
        i += 1;
    }
    // Unmasked bytes pass through verbatim (multibyte sequences intact);
    // masked bytes become ASCII spaces — the result stays valid UTF-8.
    Masked {
        code: String::from_utf8(code).expect("masking preserves UTF-8"),
        comments: String::from_utf8(comments).expect("masking preserves UTF-8"),
    }
}

/// If `bytes[at..]` starts `#*"` (zero or more hashes then a quote),
/// return the hash count — i.e. position `at` is just past the `r` of a
/// raw-string opener. Guards against identifiers like `ring` by requiring
/// the preceding character (before the `r`) to be a non-ident boundary,
/// which the caller established by matching the `r` in code state.
fn raw_str_hashes(bytes: &[u8], at: usize) -> Option<usize> {
    let h = bytes[at..].iter().take_while(|&&c| c == b'#').count();
    if bytes.get(at + h) == Some(&b'"') {
        Some(h)
    } else {
        None
    }
}

/// Heuristic: does the `'` at `at` open a char literal (vs a lifetime)?
fn is_char_literal(bytes: &[u8], at: usize) -> bool {
    match bytes.get(at + 1) {
        Some(b'\\') => true, // '\n', '\'', '\u{..}' — always a literal
        Some(_) => {
            // 'x' closes right after one (possibly multibyte) char; a
            // lifetime never has a closing quote. Scan a short window.
            bytes[at + 1..].iter().take(5).skip(1).take_while(|&&c| c != b'\n').any(|&c| c == b'\'')
        }
        None => false,
    }
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// All word-bounded occurrences of `token` in `hay` (byte offsets).
/// Boundary = the bytes adjacent to the match are not identifier bytes.
/// `::` inside the token is matched literally.
fn token_positions(hay: &str, token: &str) -> Vec<usize> {
    let hb = hay.as_bytes();
    let tb = token.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(token) {
        let at = from + pos;
        let before_ok = at == 0 || !is_ident_byte(hb[at - 1]);
        let after = at + tb.len();
        let after_ok = after >= hb.len() || !is_ident_byte(hb[after]);
        // Also reject a path continuation before the token (`x::thread::spawn`
        // is still a match on `thread::spawn`; but `my_thread::spawn` must
        // not match, which the ident-boundary check already handles).
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + 1;
    }
    out
}

/// 1-based line number of byte offset `at`.
fn line_of(src: &str, at: usize) -> usize {
    src.as_bytes()[..at].iter().filter(|&&b| b == b'\n').count() + 1
}

/// Line ranges (1-based, inclusive) covered by `#[cfg(test)]`-gated items
/// (including forms like `#[cfg(all(test, not(loom)))]`). Brace-matched on
/// the masked code so strings and comments can't unbalance the scan.
fn test_regions(code: &str) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let mut regions = Vec::new();
    for at in token_positions(code, "cfg") {
        // Must look like an attribute: `#[cfg` or `#[cfg_attr` etc. — walk
        // back over whitespace to find `#[`.
        let mut k = at;
        while k > 0 && (bytes[k - 1] as char).is_whitespace() {
            k -= 1;
        }
        if k < 1 || bytes[k - 1] != b'[' || k < 2 || bytes[k - 2] != b'#' {
            continue;
        }
        // The attribute argument list: from the `(` after cfg to its
        // matching `)`.
        let Some(open) = code[at..].find('(').map(|p| at + p) else { continue };
        let mut depth = 0usize;
        let mut close = None;
        for (j, &b) in bytes.iter().enumerate().skip(open) {
            match b {
                b'(' => depth += 1,
                b')' => {
                    depth -= 1;
                    if depth == 0 {
                        close = Some(j);
                        break;
                    }
                }
                _ => {}
            }
        }
        let Some(close) = close else { continue };
        // `not(test)` gates NON-test code — drop it before looking for a
        // positive `test` token.
        let args = code[open..=close].replace("not(test)", "");
        if token_positions(&args, "test").is_empty() {
            continue;
        }
        // Gated item body: first `{` after the attribute, brace-matched. A
        // `;` first means a brace-less item (`mod tests;`) — no inline
        // region to record.
        let Some(body_open) = code[close..].find('{').map(|p| close + p) else { continue };
        if code[close..body_open].contains(';') {
            continue;
        }
        let mut depth = 0usize;
        let mut body_close = None;
        for (j, &b) in bytes.iter().enumerate().skip(body_open) {
            match b {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        body_close = Some(j);
                        break;
                    }
                }
                _ => {}
            }
        }
        let Some(body_close) = body_close else { continue };
        regions.push((line_of(code, at), line_of(code, body_close)));
    }
    regions
}

fn in_regions(regions: &[(usize, usize)], line: usize) -> bool {
    regions.iter().any(|&(a, b)| a <= line && line <= b)
}

/// Is this whole file test/bench code (exempt from rules 2–4)?
fn is_test_file(rel: &str) -> bool {
    rel.starts_with("rust/tests/") || rel.starts_with("rust/benches/")
}

/// Lint one file. `rel` is the repo-root-relative path with `/` separators
/// (rule scoping keys off it); `src` is the file contents.
pub fn lint_file(rel: &str, src: &str) -> Vec<Violation> {
    let masked = mask(src);
    let mut out = Vec::new();
    rule_safety_comment(rel, &masked, &mut out);
    if !is_test_file(rel) {
        let tests = test_regions(&masked.code);
        rule_float_ord_unwrap(rel, &masked, &tests, &mut out);
        rule_determinism(rel, &masked, &tests, &mut out);
        rule_time_funnel(rel, &masked, &tests, &mut out);
        rule_log_choke(rel, &masked, &tests, &mut out);
        rule_thread_spawn(rel, &masked, &tests, &mut out);
    }
    out
}

/// Rule `safety-comment`: every `unsafe` token is preceded (within
/// [`SAFETY_WINDOW`] lines) by a not-yet-consumed comment line containing
/// `SAFETY:` or a `# Safety` doc section. Applies to test code too —
/// test-side unsafe has the same preconditions as production unsafe.
fn rule_safety_comment(rel: &str, m: &Masked, out: &mut Vec<Violation>) {
    let mut marker_lines: Vec<usize> = m
        .comments
        .lines()
        .enumerate()
        .filter(|(_, l)| l.contains("SAFETY:") || l.contains("# Safety"))
        .map(|(i, _)| i + 1)
        .collect();
    let mut unsafe_lines: Vec<usize> =
        token_positions(&m.code, "unsafe").iter().map(|&p| line_of(&m.code, p)).collect();
    unsafe_lines.dedup();
    for line in unsafe_lines {
        // Nearest unconsumed marker at or above this line, within range.
        let found = marker_lines
            .iter()
            .rposition(|&ml| ml <= line && line - ml <= SAFETY_WINDOW);
        match found {
            Some(idx) => {
                marker_lines.remove(idx); // one marker covers one site
            }
            None => out.push(Violation {
                rule: "safety-comment",
                file: rel.to_string(),
                line,
                message: format!(
                    "`unsafe` without a `// SAFETY:` comment (or `# Safety` doc) in the {SAFETY_WINDOW} lines above — state the precondition this site relies on"
                ),
            }),
        }
    }
}

/// Rule `float-ord-unwrap`: `partial_cmp` immediately chained into
/// `.unwrap()`/`.expect(` panics on the first NaN score. Outside the
/// blessed total order in `select.rs`, route through
/// `sparsify::select::cmp_f64_nan_last` (or `f32::total_cmp`).
fn rule_float_ord_unwrap(rel: &str, m: &Masked, tests: &[(usize, usize)], out: &mut Vec<Violation>) {
    if rel == FLOAT_ORD_HOME {
        return;
    }
    for at in token_positions(&m.code, "partial_cmp") {
        let line = line_of(&m.code, at);
        if in_regions(tests, line) {
            continue;
        }
        // Same-statement window: up to the terminating `;` (or end of file
        // for expression position).
        let rest = &m.code[at..];
        let stmt_end = rest.find(';').unwrap_or(rest.len());
        let stmt = &rest[..stmt_end];
        if stmt.contains(".unwrap") || stmt.contains(".expect") {
            out.push(Violation {
                rule: "float-ord-unwrap",
                file: rel.to_string(),
                line,
                message: "`partial_cmp(..).unwrap()` panics on NaN — use \
                          `sparsify::select::cmp_f64_nan_last` / the select.rs total order"
                    .to_string(),
            });
        }
    }
}

/// Rule `determinism`: ambient RNG is banned in the deterministic paths —
/// selection sets and aggregates must be pure functions of (seed, config,
/// round). Wall clocks are covered crate-wide by [`rule_time_funnel`].
fn rule_determinism(rel: &str, m: &Masked, tests: &[(usize, usize)], out: &mut Vec<Violation>) {
    if !DETERMINISTIC_DIRS.iter().any(|d| rel.starts_with(d)) {
        return;
    }
    for token in RNG_TOKENS {
        for at in token_positions(&m.code, token) {
            let line = line_of(&m.code, at);
            if in_regions(tests, line) {
                continue;
            }
            out.push(Violation {
                rule: "determinism",
                file: rel.to_string(),
                line,
                message: format!(
                    "`{token}` in a deterministic path — bit-identity (resume, parallel==serial) \
                     requires state to be a pure function of seed/config/round"
                ),
            });
        }
    }
}

/// Rule `time-funnel`: the wall clock is read only in `obs::clock`. Every
/// other module takes time through `clock::now_ns` / `clock::Stopwatch`,
/// which keeps timestamps strictly on the observability side: the flight
/// recorder can prove zero perturbation only if no training or harness
/// code can branch on a raw clock read.
fn rule_time_funnel(rel: &str, m: &Masked, tests: &[(usize, usize)], out: &mut Vec<Violation>) {
    if rel == TIME_HOME {
        return;
    }
    for token in TIME_TOKENS {
        for at in token_positions(&m.code, token) {
            let line = line_of(&m.code, at);
            if in_regions(tests, line) {
                continue;
            }
            out.push(Violation {
                rule: "time-funnel",
                file: rel.to_string(),
                line,
                message: format!(
                    "`{token}` outside obs::clock — read time via \
                     `obs::clock::now_ns()` / `obs::clock::Stopwatch` so every \
                     timestamp flows through the one audited choke point"
                ),
            });
        }
    }
}

/// Rule `log-choke`: ad-hoc `eprintln!` is banned outside the leveled log
/// sink (`obs::log`) and the CLI entry point. Diagnostics routed through
/// `obs::log::{info,warn,error}` stay capturable in tests and visible to
/// the recorder; a raw `eprintln!` is invisible to both.
fn rule_log_choke(rel: &str, m: &Masked, tests: &[(usize, usize)], out: &mut Vec<Violation>) {
    if LOG_HOMES.contains(&rel) {
        return;
    }
    for at in token_positions(&m.code, "eprintln") {
        let line = line_of(&m.code, at);
        if in_regions(tests, line) {
            continue;
        }
        out.push(Violation {
            rule: "log-choke",
            file: rel.to_string(),
            line,
            message: "`eprintln!` outside obs::log — emit through \
                      `obs::log::{info,warn,error}` so diagnostics are leveled \
                      and capturable in tests"
                .to_string(),
        });
    }
}

/// Rule `thread-spawn`: OS threads are created only in `tensor::pool`
/// (`ScopedPool::new` + `spawn_worker_thread`) so the thread-budget
/// discipline has a single choke point.
fn rule_thread_spawn(rel: &str, m: &Masked, tests: &[(usize, usize)], out: &mut Vec<Violation>) {
    if rel == THREAD_HOME {
        return;
    }
    for at in token_positions(&m.code, "thread::spawn") {
        let line = line_of(&m.code, at);
        if in_regions(tests, line) {
            continue;
        }
        out.push(Violation {
            rule: "thread-spawn",
            file: rel.to_string(),
            line,
            message: "`thread::spawn` outside tensor::pool — use \
                      `tensor::pool::spawn_worker_thread` (budget discipline)"
                .to_string(),
        });
    }
}

/// Recursively collect `.rs` files under `dir` (sorted for stable output).
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut entries: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// The directories `verify` scans, relative to the repo root. `xtask/`,
/// `loom/`, and `fuzz/` are harness code and out of scope.
const SCAN_DIRS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

/// Run every rule over the tree at `root`. Returns all violations, stably
/// ordered by (file, line).
pub fn verify(root: &Path) -> std::io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    for d in SCAN_DIRS {
        rs_files(&root.join(d), &mut files);
    }
    let mut out = Vec::new();
    for path in files {
        let src = std::fs::read_to_string(&path)?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        out.extend(lint_file(&rel, &src));
    }
    out.sort_by(|a, b| a.file.cmp(&b.file).then(a.line.cmp(&b.line)));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- seeded negative tests: every rule must FIRE on its violation ----

    #[test]
    fn safety_comment_rule_fires_on_undocumented_unsafe() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { p.read() }\n}\n";
        let v = lint_file("rust/src/tensor/bad.rs", src);
        assert!(
            v.iter().any(|v| v.rule == "safety-comment" && v.line == 2),
            "expected safety-comment violation, got {v:?}"
        );
    }

    #[test]
    fn safety_comment_rule_accepts_documented_unsafe() {
        let src = "fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { p.read() }\n}\n";
        assert!(lint_file("rust/src/tensor/ok.rs", src).is_empty());
    }

    #[test]
    fn safety_doc_section_counts_for_unsafe_fn() {
        let src = "/// Reads a byte.\n///\n/// # Safety\n///\n/// `p` must be valid for reads.\npub unsafe fn f(p: *const u8) -> u8 {\n    p.read()\n}\n";
        assert!(lint_file("rust/src/tensor/ok2.rs", src).is_empty());
    }

    #[test]
    fn one_safety_comment_does_not_cover_two_unsafe_sites() {
        let src = "fn f(p: *const u8) {\n    // SAFETY: p valid.\n    unsafe { p.read() };\n    unsafe { p.read() };\n}\n";
        let v = lint_file("rust/src/tensor/two.rs", src);
        assert_eq!(v.len(), 1, "second site must need its own comment: {v:?}");
        assert_eq!(v[0].line, 4);
    }

    #[test]
    fn safety_comment_applies_in_test_code_too() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let p = &0u8 as *const u8;\n        unsafe { p.read() };\n    }\n}\n";
        let v = lint_file("rust/src/tensor/tt.rs", src);
        assert!(v.iter().any(|v| v.rule == "safety-comment"));
    }

    #[test]
    fn safety_comment_rule_covers_unsafe_trait_impls() {
        // The site class introduced by the fused row sinks: an
        // `unsafe impl Sync` whose soundness rests on a driver-level
        // disjointness contract must state it like any other unsafe site.
        let bad = "struct Sink(*mut f32);\nunsafe impl Sync for Sink {}\n";
        let v = lint_file("rust/src/tensor/sink_bad.rs", bad);
        assert!(
            v.iter().any(|v| v.rule == "safety-comment" && v.line == 2),
            "expected safety-comment violation on the unsafe impl, got {v:?}"
        );
        let good = "struct Sink(*mut f32);\n// SAFETY: tasks write disjoint row groups, so shared\n// `&Sink` access never aliases a mutation.\nunsafe impl Sync for Sink {}\n";
        assert!(lint_file("rust/src/tensor/sink_ok.rs", good).is_empty());
    }

    #[test]
    fn unsafe_in_comment_or_string_is_ignored() {
        let src = "// this mentions unsafe code but has none\nfn f() -> &'static str {\n    \"unsafe { }\"\n}\n";
        assert!(lint_file("rust/src/tensor/s.rs", src).is_empty());
    }

    #[test]
    fn float_ord_unwrap_rule_fires() {
        let src = "pub fn sort(v: &mut [f32]) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let v = lint_file("rust/src/stats/bad.rs", src);
        assert!(
            v.iter().any(|v| v.rule == "float-ord-unwrap" && v.line == 2),
            "expected float-ord-unwrap violation, got {v:?}"
        );
    }

    #[test]
    fn float_ord_expect_also_fires() {
        let src = "pub fn m(a: f64, b: f64) -> std::cmp::Ordering {\n    a.partial_cmp(&b).expect(\"no NaN\")\n}\n";
        let v = lint_file("rust/src/metrics/bad.rs", src);
        assert!(v.iter().any(|v| v.rule == "float-ord-unwrap"));
    }

    #[test]
    fn float_ord_unwrap_allowed_in_select_rs_and_tests() {
        let src = "pub fn cmp(a: f32, b: f32) -> std::cmp::Ordering {\n    b.partial_cmp(&a).unwrap()\n}\n";
        assert!(lint_file("rust/src/sparsify/select.rs", src).is_empty());
        let test_src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let _ = 1.0f32.partial_cmp(&2.0).unwrap();\n    }\n}\n";
        assert!(lint_file("rust/src/stats/mod.rs", test_src).is_empty());
    }

    #[test]
    fn bare_partial_cmp_without_unwrap_is_allowed() {
        let src = "pub fn m(a: f64, b: f64) -> Option<std::cmp::Ordering> {\n    a.partial_cmp(&b)\n}\n";
        assert!(lint_file("rust/src/metrics/ok.rs", src).is_empty());
    }

    #[test]
    fn determinism_rule_fires_on_rng_in_deterministic_path() {
        for token in ["thread_rng()", "rand::random::<u64>()", "Pcg64::from_entropy()"] {
            let src = format!("pub fn f() {{\n    let _t = {token};\n}}\n");
            let v = lint_file("rust/src/sparsify/bad.rs", &src);
            assert!(
                v.iter().any(|v| v.rule == "determinism" && v.line == 2),
                "expected determinism violation for {token}, got {v:?}"
            );
        }
    }

    #[test]
    fn determinism_rule_scoped_to_deterministic_dirs() {
        let src = "pub fn f() {\n    let _r = thread_rng();\n}\n";
        // Ambient RNG is (lint-)fine outside the deterministic paths...
        assert!(lint_file("rust/src/bench/mod.rs", src).is_empty());
        assert!(lint_file("rust/src/experiments/fig_scale.rs", src).is_empty());
        // ... and in tests inside a deterministic dir.
        let test_src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let _ = thread_rng();\n    }\n}\n";
        assert!(lint_file("rust/src/coordinator/mod.rs", test_src).is_empty());
    }

    #[test]
    fn time_funnel_rule_fires_crate_wide() {
        for token in ["Instant::now()", "SystemTime::now()", "UNIX_EPOCH"] {
            let src = format!("pub fn f() {{\n    let _t = {token};\n}}\n");
            // Fires even outside the deterministic dirs — bench layer,
            // experiments, examples all funnel through obs::clock now.
            for rel in
                ["rust/src/bench/mod.rs", "rust/src/experiments/fig_scale.rs", "examples/probe.rs"]
            {
                let v = lint_file(rel, &src);
                assert!(
                    v.iter().any(|v| v.rule == "time-funnel" && v.line == 2),
                    "expected time-funnel violation for {token} in {rel}, got {v:?}"
                );
            }
        }
    }

    #[test]
    fn time_funnel_allowed_in_clock_home_tests_and_test_files() {
        let src = "pub fn f() {\n    let _t = Instant::now();\n}\n";
        assert!(lint_file("rust/src/obs/clock.rs", src).is_empty());
        assert!(lint_file("rust/tests/integration.rs", src).is_empty());
        assert!(lint_file("rust/benches/e2e_iter.rs", src).is_empty());
        let test_src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let _ = Instant::now();\n    }\n}\n";
        assert!(lint_file("rust/src/metrics/mod.rs", test_src).is_empty());
    }

    #[test]
    fn log_choke_rule_fires_outside_log_sink() {
        let src = "pub fn f() {\n    eprintln!(\"warning: something\");\n}\n";
        for rel in ["rust/src/coordinator/snapshot.rs", "rust/src/experiments/fig6.rs"] {
            let v = lint_file(rel, src);
            assert!(
                v.iter().any(|v| v.rule == "log-choke" && v.line == 2),
                "expected log-choke violation in {rel}, got {v:?}"
            );
        }
    }

    #[test]
    fn log_choke_allowed_in_log_sink_main_and_tests() {
        let src = "pub fn f() {\n    eprintln!(\"warning: something\");\n}\n";
        assert!(lint_file("rust/src/obs/log.rs", src).is_empty());
        assert!(lint_file("rust/src/main.rs", src).is_empty());
        assert!(lint_file("rust/tests/integration.rs", src).is_empty());
        let test_src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        eprintln!(\"debug\");\n    }\n}\n";
        assert!(lint_file("rust/src/runtime/engine.rs", test_src).is_empty());
    }

    #[test]
    fn thread_spawn_rule_fires_outside_pool() {
        let src = "pub fn f() {\n    std::thread::spawn(|| {});\n}\n";
        let v = lint_file("rust/src/coordinator/bad.rs", src);
        assert!(
            v.iter().any(|v| v.rule == "thread-spawn" && v.line == 2),
            "expected thread-spawn violation, got {v:?}"
        );
    }

    #[test]
    fn thread_spawn_allowed_in_pool_tests_and_bench_files() {
        let src = "pub fn f() {\n    std::thread::spawn(|| {});\n}\n";
        assert!(lint_file("rust/src/tensor/pool.rs", src).is_empty());
        assert!(lint_file("rust/tests/integration.rs", src).is_empty());
        assert!(lint_file("rust/benches/gemm_par.rs", src).is_empty());
        let test_src = "#[cfg(all(test, not(loom)))]\nmod tests {\n    #[test]\n    fn t() {\n        std::thread::spawn(|| {}).join().unwrap();\n    }\n}\n";
        assert!(lint_file("rust/src/coordinator/ring.rs", test_src).is_empty());
    }

    // ---- masking machinery ----

    #[test]
    fn masking_blanks_comments_strings_chars_and_raw_strings() {
        let src = r##"fn f() { let s = "unsafe"; let r = r#"thread::spawn"#; let c = 'u'; } // unsafe"##;
        let m = mask(src);
        assert!(!m.code.contains("unsafe"));
        assert!(!m.code.contains("thread::spawn"));
        assert!(m.comments.contains("unsafe"));
        assert_eq!(m.code.len(), src.len());
    }

    #[test]
    fn masking_keeps_lifetimes_as_code() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }";
        let m = mask(src);
        assert!(m.code.contains("&'a str"));
    }

    #[test]
    fn token_matching_respects_word_boundaries() {
        assert_eq!(token_positions("let unsafety = 1;", "unsafe").len(), 0);
        assert_eq!(token_positions("unsafe { }", "unsafe").len(), 1);
        assert_eq!(token_positions("my_thread::spawn()", "thread::spawn").len(), 0);
        assert_eq!(token_positions("std::thread::spawn()", "thread::spawn").len(), 1);
    }

    #[test]
    fn test_region_detection_brace_matches() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() { let x = \"}\"; }\n}\nfn c() {}\n";
        let m = mask(src);
        let r = test_regions(&m.code);
        assert_eq!(r.len(), 1);
        assert!(in_regions(&r, 3) && in_regions(&r, 4) && in_regions(&r, 5));
        assert!(!in_regions(&r, 1) && !in_regions(&r, 6));
    }

    // ---- the tree itself must be clean ----

    #[test]
    fn repo_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().to_path_buf();
        let violations = verify(&root).expect("scan repo");
        assert!(
            violations.is_empty(),
            "lint violations in tree:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
