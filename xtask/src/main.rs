//! `cargo xtask verify` — run the repo lint pass (see lib.rs for rules).

use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> ExitCode {
    eprintln!("usage: cargo xtask verify [--root <repo-root>]");
    ExitCode::from(2)
}

/// The repo root: `--root` wins; else the working directory when it looks
/// like the repo (the `cargo xtask` alias runs from the workspace root);
/// else the parent of this crate's manifest dir.
fn resolve_root(explicit: Option<PathBuf>) -> PathBuf {
    if let Some(r) = explicit {
        return r;
    }
    if let Ok(cwd) = std::env::current_dir() {
        if cwd.join("rust/src").is_dir() {
            return cwd;
        }
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask has a parent dir").to_path_buf()
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let Some(cmd) = args.next() else { return usage() };
    if cmd != "verify" {
        return usage();
    }
    let mut root = None;
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => return usage(),
            },
            _ => return usage(),
        }
    }
    let root = resolve_root(root);
    match xtask::verify(&root) {
        Ok(violations) if violations.is_empty() => {
            println!("xtask verify: ok ({} clean)", root.display());
            ExitCode::SUCCESS
        }
        Ok(violations) => {
            for v in &violations {
                eprintln!("{v}");
            }
            eprintln!("xtask verify: {} violation(s)", violations.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("xtask verify: scan failed: {e}");
            ExitCode::FAILURE
        }
    }
}
