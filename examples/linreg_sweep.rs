//! Linear-regression experiment sweep: regenerates Figs. 3, 4, 5 and 8
//! (plus the Table 2 trace) from the library API.
//!
//! ```bash
//! cargo run --release --example linreg_sweep            # paper scale
//! cargo run --release --example linreg_sweep -- --fast  # smoke scale
//! ```

use regtopk::experiments::{self, ExpOpts};

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let opts = ExpOpts { fast, ..Default::default() };
    std::fs::create_dir_all(&opts.out_dir)?;
    for id in ["fig3", "fig4", "fig5", "fig8", "table2"] {
        println!("\n=== {id} ===");
        experiments::run(id, &opts)?;
    }
    println!("\nCSVs under {}", opts.out_dir.display());
    Ok(())
}
