use regtopk::experiments::fig3::{run_policy, Size, MU};
use regtopk::obs::clock::Stopwatch;
use regtopk::sparsify::SparsifierKind;
fn main() {
    let size = Size { workers: 20, dim: 100, points: 500, iters: 2500 };
    let t0 = Stopwatch::start();
    let r = run_policy(&size, SparsifierKind::RegTopK { mu: MU, y: 1.0 }, 0.6, 0).unwrap();
    println!("one paper-scale 2500-iter run: {:.2?}  final={:.3e}", t0.elapsed(), r.final_gap());
}
