//! The fine-tuning evaluation suite: regenerates Table 1 (five model
//! variants × two sparsity levels × paired seeds with significance tests)
//! and the Fig. 7 μ-sweep.
//!
//! ```bash
//! cargo run --release --example finetune_suite [-- --fast]
//! ```

use regtopk::experiments::{self, ExpOpts};

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let opts = ExpOpts { fast, ..Default::default() };
    std::fs::create_dir_all(&opts.out_dir)?;
    println!("=== Table 1: fine-tuning suite ===");
    experiments::run("table1", &opts)?;
    println!("\n=== Fig 7: mu sweep ===");
    experiments::run("fig7", &opts)?;
    Ok(())
}
