//! Quickstart: distributed linear regression with REGTOP-k in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Trains the paper's §5.1 workload (N = 20 workers, J = 100) at 60%
//! sparsity with both TOP-k and REGTOP-k and prints the optimality gap
//! and the exact communication bill. When AOT artifacts are present it
//! also demonstrates the production path: the same protocol with the
//! local gradient computed by the JAX/Pallas-compiled `linreg_grad`
//! artifact through PJRT.

use regtopk::config::TrainConfig;
use regtopk::coordinator::{run_linreg, RunOpts};
use regtopk::sparsify::SparsifierKind;

fn main() -> anyhow::Result<()> {
    for (name, kind) in [
        ("topk", SparsifierKind::TopK),
        ("regtopk", SparsifierKind::RegTopK { mu: 1.0, y: 1.0 }),
    ] {
        let cfg = TrainConfig {
            workers: 20,
            dim: 100,
            sparsity: 0.6,
            sparsifier: kind,
            lr: 0.01,
            iters: 1500,
            seed: 0,
            log_every: 100,
            ..Default::default()
        };
        let report = run_linreg(&cfg, &RunOpts::default())?;
        println!(
            "{name:<8} S=0.6: final gap {:.3e}   uplink {:.1} KiB   downlink {:.1} KiB",
            report.final_gap(),
            report.result.comm.uplink_bytes() as f64 / 1024.0,
            report.result.comm.downlink_bytes() as f64 / 1024.0,
        );
    }
    println!("\n(regtopk converges to the optimum; topk stalls — the paper's Fig. 3)");

    // Production path: same worker gradient as an AOT-compiled artifact.
    let dir = regtopk::runtime::hlo_grad::default_artifacts_dir();
    if regtopk::runtime::Manifest::available(&dir) {
        hlo_demo(&dir)?;
    } else {
        println!("run `make artifacts` to also exercise the PJRT path");
    }
    Ok(())
}

/// Single-worker gradient descent where every gradient is an artifact
/// execution (the three-layer path: Pallas kernel -> JAX -> HLO -> PJRT).
fn hlo_demo(dir: &str) -> anyhow::Result<()> {
    use regtopk::grad::WorkerGrad;
    use regtopk::rng::Pcg64;
    use regtopk::runtime::hlo_grad::{open_engine, HloGrad};
    use regtopk::tensor::Matrix;

    let engine = open_engine(dir)?;
    let entry = engine.borrow_mut().entry("linreg_grad")?;
    let (d, j) = (entry.meta_usize("points").unwrap(), entry.meta_usize("dim").unwrap());
    let mut rng = Pcg64::seed_from_u64(0);
    let truth = rng.normal_vec(j, 0.0, 1.0);
    let x = Matrix::from_vec(d, j, rng.normal_vec(d * j, 0.0, 1.0));
    let mut y = vec![0.0f32; d];
    x.matvec(&truth, &mut y);
    let mut worker =
        HloGrad::new(engine, "linreg_grad", HloGrad::static_feeder(vec![x.data, y]))?;
    let mut theta = vec![0.0f32; j];
    let mut g = vec![0.0f32; j];
    let first = worker.grad(0, &theta, &mut g);
    for t in 0..100 {
        worker.grad(t, &theta, &mut g);
        for (p, gi) in theta.iter_mut().zip(g.iter()) {
            *p -= 0.01 * gi;
        }
    }
    let last = worker.grad(100, &theta, &mut g);
    println!("PJRT path: linreg_grad artifact, loss {first:.3} -> {last:.3e} in 100 GD steps");
    Ok(())
}
