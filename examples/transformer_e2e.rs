//! END-TO-END VALIDATION DRIVER: distributed training of a transformer
//! language model where every local gradient is an AOT-compiled JAX
//! artifact executed via PJRT, and every update travels through the
//! sparsified parameter-server protocol.
//!
//! All three layers compose here:
//!   L1/L2  python/compile/model_transformer.py (+ Pallas score kernel in
//!          the same compile pipeline) -> artifacts/transformer_grad.hlo.txt
//!   L3     this binary: rust coordinator, REGTOP-k sparsifier, Adam server
//!
//! ```bash
//! make artifacts && cargo run --release --example transformer_e2e [-- --fast]
//! ```
//!
//! Scale note (DESIGN.md §4): the testbed is one CPU core, so the model
//! is ~0.44M parameters rather than the ~100M a TPU pod run would use;
//! every code path (flat-parameter sparsification, artifact execution,
//! sparse aggregation, posterior-distortion feedback) is identical.

use regtopk::config::{OptimizerKind, TrainConfig};
use regtopk::coordinator::{train, IterStats};
use regtopk::data::{TokenCorpus, TokenGenConfig};
use regtopk::grad::WorkerGrad;
use regtopk::metrics::{AsciiPlot, Curves};
use regtopk::rng::Pcg64;
use regtopk::runtime::hlo_grad::{open_engine, Feeder, HloGrad, SharedEngine};
use regtopk::sparsify::SparsifierKind;
use std::rc::Rc;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let dir = regtopk::runtime::hlo_grad::default_artifacts_dir();
    anyhow::ensure!(
        regtopk::runtime::Manifest::available(&dir),
        "transformer_e2e requires artifacts — run `make artifacts` first"
    );
    let engine = open_engine(&dir)?;
    let entry = engine.borrow_mut().entry("transformer_grad")?;
    let dim = entry.inputs[0].elements();
    let vocab = entry.meta_usize("vocab").unwrap();
    let seq = entry.meta_usize("seq").unwrap();
    let batch = entry.meta_usize("batch").unwrap();
    let workers_n = entry.meta_usize("workers").unwrap();
    println!("transformer: J = {dim} params, vocab {vocab}, seq {seq}, N = {workers_n}");

    // Synthetic Markov corpus, sharded per worker + a held-out set.
    let gen = TokenGenConfig {
        vocab,
        seq_len: seq,
        per_worker: 256,
        workers: workers_n,
        peakiness: 8.0,
        heterogeneity: 0.25,
    };
    let corpus = Arc::new(TokenCorpus::generate(&gen, &mut Pcg64::seed_from_u64(7)));
    let val = TokenCorpus::generate(
        &TokenGenConfig { per_worker: batch * 4, workers: 1, heterogeneity: 0.0, ..gen },
        &mut Pcg64::seed_from_u64(7),
    );

    // Initial parameters from the compile side (seeded jax init).
    let theta0 = read_f32(&format!("{dir}/transformer_grad.init.f32"))?;
    anyhow::ensure!(theta0.len() == dim);

    let steps = if fast { 30 } else { 300 };
    let sparsity = 0.01; // 1% of J — k ≈ 4378 entries per worker per step
    let mut curves = Curves::new();
    for (name, kind, s) in [
        ("dense", SparsifierKind::Dense, 1.0),
        ("topk", SparsifierKind::TopK, sparsity),
        ("regtopk", SparsifierKind::RegTopK { mu: 3.0, y: 1.0 }, s_or(sparsity)),
    ] {
        let t0 = regtopk::obs::clock::Stopwatch::start();
        let cfg = TrainConfig {
            workers: workers_n,
            dim,
            sparsity: s,
            sparsifier: kind,
            lr: 1e-3,
            optimizer: OptimizerKind::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 },
            iters: steps,
            seed: 0,
            ..Default::default()
        };
        let workers = build_workers(&engine, &corpus, workers_n, batch, seq)?;
        let eval_every = (steps / 15).max(1);
        let series = curves.series_mut(name);
        let mut val_pts: Vec<(usize, f64)> = Vec::new();
        let result = train(&cfg, theta0.clone(), workers, &mut |st: IterStats<'_>| {
            series.push(st.t, st.mean_loss);
            if st.t % eval_every == 0 {
                val_pts.push((st.t, f64::NAN)); // placeholder; filled below
            }
        })?;
        // Validation loss of the final model.
        let val_loss = evaluate(&engine, &val, &result.theta, batch, seq)?;
        let train_final = curves.get(name).unwrap().last_value().unwrap();
        println!(
            "{name:<8} S={s:<5} {} steps in {:.1?}: train loss {:.4} -> {:.4}, val {:.4}, \
             uplink {:.1} MiB (vs {:.1} MiB dense)",
            steps,
            t0.elapsed(),
            curves.get(name).unwrap().points[0].1,
            train_final,
            val_loss,
            result.comm.uplink_bytes() as f64 / (1024.0 * 1024.0),
            (dim * 4 * steps * workers_n) as f64 / (1024.0 * 1024.0),
        );
    }
    std::fs::create_dir_all("results")?;
    curves.write_csv("results/e2e_transformer_loss.csv")?;
    let mut plot = AsciiPlot::new(format!(
        "e2e transformer ({dim} params, N={workers_n}): train loss vs step  [ln(V) = {:.2}]",
        (vocab as f64).ln()
    ));
    plot.add('-', curves.get("dense").unwrap());
    plot.add('o', curves.get("topk").unwrap());
    plot.add('x', curves.get("regtopk").unwrap());
    println!("{}", plot.render());
    println!("wrote results/e2e_transformer_loss.csv");
    Ok(())
}

fn s_or(s: f64) -> f64 {
    s
}

fn build_workers(
    engine: &SharedEngine,
    corpus: &Arc<TokenCorpus>,
    n: usize,
    batch: usize,
    seq: usize,
) -> anyhow::Result<Vec<Box<dyn WorkerGrad>>> {
    (0..n)
        .map(|w| {
            let corpus = Arc::clone(corpus);
            let feeder: Feeder = Box::new(move |t, bufs: &mut Vec<Vec<f32>>| {
                if bufs.is_empty() {
                    bufs.push(vec![0.0; batch * seq]);
                }
                let idx = corpus.batch_indices(w, t, batch, 42);
                for (b, &i) in idx.iter().enumerate() {
                    for (j, &tok) in corpus.shards[w][i].iter().enumerate() {
                        bufs[0][b * seq + j] = tok as f32;
                    }
                }
            });
            Ok(Box::new(HloGrad::new(Rc::clone(engine), "transformer_grad", feeder)?)
                as Box<dyn WorkerGrad>)
        })
        .collect()
}

fn evaluate(
    engine: &SharedEngine,
    val: &TokenCorpus,
    theta: &[f32],
    batch: usize,
    seq: usize,
) -> anyhow::Result<f64> {
    let seqs = &val.shards[0];
    let mut total = 0.0;
    let mut count = 0usize;
    let mut buf = vec![0.0f32; batch * seq];
    for chunk in seqs.chunks(batch) {
        if chunk.len() < batch {
            break;
        }
        for (b, s) in chunk.iter().enumerate() {
            for (j, &tok) in s.iter().enumerate() {
                buf[b * seq + j] = tok as f32;
            }
        }
        let outs = engine.borrow_mut().run_f32("transformer_eval", &[theta, &buf])?;
        total += outs[0][0] as f64;
        count += 1;
    }
    Ok(total / count.max(1) as f64)
}

fn read_f32(path: &str) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}
