//! The §1.3 motivational toy example (Figure 1), driven through the
//! public API — and, when artifacts exist, through the AOT toy artifact
//! to show the native and compiled gradients agree bit-tightly.
//!
//! ```bash
//! cargo run --release --example toy_logistic
//! ```

use regtopk::experiments::fig1;
use regtopk::models::ToyLogistic;
use regtopk::sparsify::SparsifierKind;

fn main() -> anyhow::Result<()> {
    println!("Toy logistic (J=2, N=2, eta=0.9, theta0=[0,1]) — paper Fig. 1\n");
    println!("{:<6} {:>12} {:>12} {:>12}", "iter", "topk", "regtopk", "dense");
    let topk = fig1::run_policy(SparsifierKind::TopK, 100)?;
    let reg = fig1::run_policy(SparsifierKind::RegTopK { mu: 1.0, y: 1.0 }, 100)?;
    let dense = fig1::run_policy(SparsifierKind::Dense, 100)?;
    for i in (0..100).step_by(10) {
        println!(
            "{:<6} {:>12.6} {:>12.6} {:>12.6}",
            topk[i].0, topk[i].1, reg[i].1, dense[i].1
        );
    }
    println!("\nTOP-1 stalls (the +/-100 entries cancel at the server);");
    println!("REGTOP-1 detects the cancellation via the posterior distortion.");

    // Cross-check the native gradient against the AOT artifact.
    let dir = regtopk::runtime::hlo_grad::default_artifacts_dir();
    if regtopk::runtime::Manifest::available(&dir) {
        let engine = regtopk::runtime::hlo_grad::open_engine(&dir)?;
        let theta = [0.0f32, 1.0];
        for w in ToyLogistic::paper_workers() {
            let outs = engine
                .borrow_mut()
                .run_f32("toy_logistic_grad", &[&theta, &w.x])?;
            let mut native = vec![0.0f32; 2];
            w.grad(&theta, &mut native);
            let delta = (outs[0][0] - native[0]).abs().max((outs[0][1] - native[1]).abs());
            println!(
                "artifact vs native gradient for x={:?}: max |delta| = {delta:.2e}",
                w.x
            );
            anyhow::ensure!(delta < 1e-5, "gradient mismatch");
        }
    }
    Ok(())
}
