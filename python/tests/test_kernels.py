"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and hyperparameters; assert_allclose against the
reference is the CORE correctness signal of the compile path.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import linreg_grad as lk
from compile.kernels import regtopk_score as sk
from compile.kernels import ref


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# regtopk_score
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    j=st.integers(min_value=1, max_value=3000),
    mu=st.floats(min_value=0.0, max_value=10.0),
    omega=st.floats(min_value=0.01, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_score_kernel_matches_ref(j, mu, omega, seed):
    r = rng(seed)
    a = r.normal(0, 3, j).astype(np.float32)
    a_prev = r.normal(0, 3, j).astype(np.float32)
    g_prev = r.normal(0, 1, j).astype(np.float32)
    mask = (r.random(j) < 0.5).astype(np.float32)
    scalars = np.array([omega, mu], np.float32)
    out = sk.regtopk_score(a, a_prev, g_prev, mask, scalars)
    expect = ref.regtopk_score_ref(a, a_prev, g_prev, mask, omega, mu)
    assert out.shape == (j,)
    assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-6)


def test_score_mu_zero_is_topk_prior():
    r = rng(0)
    a = r.normal(0, 1, 257).astype(np.float32)
    out = sk.regtopk_score(
        a, a.copy(), a.copy(), np.ones(257, np.float32), np.array([0.5, 0.0], np.float32)
    )
    assert_allclose(np.asarray(out), np.abs(a), rtol=1e-6)


def test_score_cancellation_damps_to_zero():
    # g_prev == 0 while omega*a_prev != 0 -> delta = -1 -> tanh(0) = 0.
    j = 64
    a = np.full(j, 5.0, np.float32)
    a_prev = np.full(j, 5.0, np.float32)
    g_prev = np.zeros(j, np.float32)
    mask = np.ones(j, np.float32)
    out = sk.regtopk_score(a, a_prev, g_prev, mask, np.array([0.5, 1.0], np.float32))
    assert np.max(np.abs(np.asarray(out))) < 1e-6


def test_score_zero_prev_guard():
    # a_prev = 0 on a masked entry must not produce NaN/Inf.
    a = np.array([1.0, 2.0], np.float32)
    a_prev = np.array([0.0, 1.0], np.float32)
    g_prev = np.array([1.0, 1.0], np.float32)
    mask = np.ones(2, np.float32)
    out = np.asarray(
        sk.regtopk_score(a, a_prev, g_prev, mask, np.array([0.5, 1.0], np.float32))
    )
    assert np.all(np.isfinite(out))
    # Guarded entry falls back to the TOP-k prior |a|.
    assert_allclose(out[0], 1.0, rtol=1e-6)


def test_score_unmasked_entries_keep_prior():
    r = rng(1)
    a = r.normal(0, 1, 100).astype(np.float32)
    out = sk.regtopk_score(
        a,
        r.normal(0, 1, 100).astype(np.float32),
        r.normal(0, 1, 100).astype(np.float32),
        np.zeros(100, np.float32),
        np.array([0.5, 2.0], np.float32),
    )
    assert_allclose(np.asarray(out), np.abs(a), rtol=1e-6)


# ---------------------------------------------------------------------------
# linreg_grad
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=400),
    j=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_linreg_kernel_matches_ref(d, j, seed):
    r = rng(seed)
    x = r.normal(0, 1, (d, j)).astype(np.float32)
    y = r.normal(0, 1, d).astype(np.float32)
    theta = r.normal(0, 1, j).astype(np.float32)
    g, loss = lk.linreg_grad(theta, x, y)
    expect = ref.linreg_grad_ref(theta, x, y)
    assert_allclose(np.asarray(g), np.asarray(expect), rtol=2e-4, atol=2e-4)
    expect_loss = float(np.mean((x @ theta - y) ** 2))
    assert_allclose(float(loss), expect_loss, rtol=1e-4)


def test_linreg_paper_shape():
    # The exact Fig. 3 shape: D=500, J=100.
    r = rng(7)
    x = r.normal(0, 1, (500, 100)).astype(np.float32)
    truth = r.normal(0, 1, 100).astype(np.float32)
    y = (x @ truth).astype(np.float32)
    g, loss = lk.linreg_grad(truth, x, y)
    # At the generating model with no noise the gradient vanishes.
    assert float(jnp.max(jnp.abs(g))) < 1e-3
    assert float(loss) < 1e-6


def test_linreg_grad_descends():
    r = rng(8)
    x = r.normal(0, 1, (120, 30)).astype(np.float32)
    truth = r.normal(0, 1, 30).astype(np.float32)
    y = (x @ truth).astype(np.float32)
    theta = np.zeros(30, np.float32)
    _, loss0 = lk.linreg_grad(theta, x, y)
    for _ in range(60):
        g, _ = lk.linreg_grad(theta, x, y)
        theta = theta - 0.01 * np.asarray(g)
    _, loss1 = lk.linreg_grad(theta, x, y)
    assert float(loss1) < 0.1 * float(loss0)
