"""AOT pipeline tests: lowering works, HLO text is parseable-ish, the
manifest round-trips, and a lowered module re-executes correctly through
the XLA client (the same path the rust runtime takes)."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import aot, model
from jax._src.lib import xla_client as xc


def test_to_hlo_text_produces_module():
    text = aot.to_hlo_text(
        model.toy_logistic_grad_entry, (aot.spec(2), aot.spec(2))
    )
    assert "HloModule" in text
    assert "ROOT" in text


def test_hlo_text_declares_expected_signature():
    # The lowered linreg module must expose 3 parameters and a tuple root
    # (return_tuple=True) — the contract the rust loader relies on.
    # (End-to-end numeric validation of the text round-trip lives in the
    # rust integration test engine::linreg_grad_artifact_matches_native.)
    d, j = 40, 10
    text = aot.to_hlo_text(
        model.linreg_grad_entry, (aot.spec(j), aot.spec(d, j), aot.spec(d))
    )
    assert "HloModule" in text
    assert text.count("parameter(") >= 3
    assert f"f32[{d},{j}]" in text


def test_manifest_written_and_complete():
    with tempfile.TemporaryDirectory() as tmp:
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out", tmp, "--only", "toy_logistic_grad,linreg_grad"]
        try:
            aot.main()
        finally:
            sys.argv = argv
        with open(os.path.join(tmp, "manifest.json")) as f:
            manifest = json.load(f)
        names = [e["name"] for e in manifest["entries"]]
        assert names == ["linreg_grad", "toy_logistic_grad"]
        for e in manifest["entries"]:
            path = os.path.join(tmp, e["file"])
            assert os.path.exists(path)
            assert os.path.getsize(path) > 100
            assert all("shape" in t for t in e["inputs"])
            assert all("shape" in t for t in e["outputs"])


def test_entry_registry_is_consistent():
    for name, fn, example, in_names, out_names, meta, _init in aot.entries():
        assert len(in_names) == len(example), name
        out = jax.eval_shape(fn, *example)
        if not isinstance(out, (tuple, list)):
            out = (out,)
        assert len(out_names) == len(out), name
        # Gradient output (when present) matches theta shape.
        if out_names[0] == "grad":
            assert out[0].shape == example[0].shape, name
        assert "dim" in meta, name


def test_init_files_match_dims():
    for name, _fn, example, _i, _o, meta, init_fn in aot.entries():
        if init_fn is None:
            continue
        init = init_fn()
        assert init.shape == example[0].shape, name
        assert bool(jnp.all(jnp.isfinite(init))), name
