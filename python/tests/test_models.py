"""L2 model tests: shapes, gradient identities, learnability."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model, model_cnn, model_mlp, model_transformer


def rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# toy logistic
# ---------------------------------------------------------------------------


def test_toy_logistic_matches_closed_form():
    theta = jnp.array([0.0, 1.0], jnp.float32)
    x = jnp.array([100.0, 1.0], jnp.float32)
    g, loss = model.toy_logistic_grad_entry(theta, x)
    z = 1.0
    coeff = -(1.0 - 1.0 / (1.0 + np.exp(-z)))
    assert_allclose(np.asarray(g), coeff * np.asarray(x), rtol=1e-5)
    assert_allclose(float(loss), np.log(1 + np.exp(-z)), rtol=1e-5)


def test_toy_logistic_mirrored_workers_cancel():
    theta = jnp.array([0.0, 1.0], jnp.float32)
    g1, _ = model.toy_logistic_grad_entry(theta, jnp.array([100.0, 1.0]))
    g2, _ = model.toy_logistic_grad_entry(theta, jnp.array([-100.0, 1.0]))
    assert_allclose(float(g1[0] + g2[0]), 0.0, atol=1e-6)
    assert_allclose(float(g1[1] - g2[1]), 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# MLP (must mirror the rust native model exactly)
# ---------------------------------------------------------------------------


def test_mlp_zero_params_uniform_loss():
    i, h, c, b = 6, 4, 3, 5
    theta = jnp.zeros(model_mlp.dims(i, h, c), jnp.float32)
    x = jnp.asarray(rng().normal(0, 1, (b, i)), jnp.float32)
    y = jax.nn.one_hot(jnp.arange(b) % c, c)
    entry = model_mlp.make_grad_entry(i, h, c)
    grad, loss, acc = entry(theta, x, y)
    assert grad.shape == (model_mlp.dims(i, h, c),)
    assert_allclose(float(loss), np.log(c), rtol=1e-5)


def test_mlp_grad_is_true_gradient():
    i, h, c, b = 5, 7, 4, 3
    r = rng(1)
    theta = jnp.asarray(r.normal(0, 0.5, model_mlp.dims(i, h, c)), jnp.float32)
    x = jnp.asarray(r.normal(0, 1, (b, i)), jnp.float32)
    y = jax.nn.one_hot(jnp.arange(b) % c, c)
    entry = model_mlp.make_grad_entry(i, h, c)
    grad, loss, _ = entry(theta, x, y)
    # Directional finite difference.
    d = jnp.asarray(r.normal(0, 1, theta.shape), jnp.float32)
    d = d / jnp.linalg.norm(d)
    eps = 1e-3
    _, lp, _ = entry(theta + eps * d, x, y)
    _, lm, _ = entry(theta - eps * d, x, y)
    fd = (float(lp) - float(lm)) / (2 * eps)
    assert_allclose(fd, float(jnp.dot(grad, d)), rtol=5e-2, atol=1e-4)


# ---------------------------------------------------------------------------
# CNN
# ---------------------------------------------------------------------------


def test_cnn_shapes_and_dims():
    spec = model_cnn.CnnSpec(side=16, classes=10, c1=16, c2=32)
    theta = spec.init(jax.random.PRNGKey(0))
    assert theta.shape == (spec.dims(),)
    x = jnp.zeros((4, 3 * 16 * 16), jnp.float32)
    logits = model_cnn.forward(spec, theta, x)
    assert logits.shape == (4, 10)


def test_cnn_grad_entry_outputs():
    spec = model_cnn.CnnSpec(side=8, classes=4, c1=4, c2=8)
    entry = model_cnn.make_grad_entry(spec)
    theta = spec.init(jax.random.PRNGKey(1))
    r = rng(2)
    x = jnp.asarray(r.normal(0, 1, (4, 3 * 8 * 8)), jnp.float32)
    y = jax.nn.one_hot(jnp.arange(4) % 4, 4)
    grad, loss, acc = entry(theta, x, y)
    assert grad.shape == theta.shape
    assert float(loss) > 0
    assert 0.0 <= float(acc) <= 1.0
    assert bool(jnp.all(jnp.isfinite(grad)))


def test_cnn_learns_blob_classes():
    spec = model_cnn.CnnSpec(side=8, classes=2, c1=4, c2=8)
    entry = model_cnn.make_grad_entry(spec)
    theta = spec.init(jax.random.PRNGKey(2))
    r = rng(3)
    protos = r.normal(0, 1, (2, 3 * 64)).astype(np.float32)
    xs = np.concatenate([protos[i % 2] + r.normal(0, 0.3, 3 * 64) for i in range(32)]).reshape(
        32, -1
    ).astype(np.float32)
    ys = jax.nn.one_hot(jnp.arange(32) % 2, 2)
    step = jax.jit(lambda t: entry(t, xs, ys))
    _, loss0, _ = step(theta)
    for _ in range(60):
        g, _, _ = step(theta)
        theta = theta - 0.05 * g
    _, loss1, acc = step(theta)
    assert float(loss1) < 0.5 * float(loss0)
    assert float(acc) > 0.8


# ---------------------------------------------------------------------------
# transformer
# ---------------------------------------------------------------------------


def test_transformer_dims_and_forward():
    spec = model_transformer.TransformerSpec(vocab=32, seq=8, d=16, heads=2, layers=2, ff=32)
    theta = spec.init(jax.random.PRNGKey(0))
    assert theta.shape == (spec.dims(),)
    tokens = jnp.asarray(rng().integers(0, 32, (2, 8)), jnp.int32)
    logits = model_transformer.forward(spec, theta, tokens)
    assert logits.shape == (2, 8, 32)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_transformer_initial_loss_near_uniform():
    spec = model_transformer.TransformerSpec(vocab=64, seq=16, d=16, heads=2, layers=1, ff=32)
    theta = spec.init(jax.random.PRNGKey(1))
    tokens = jnp.asarray(rng(1).integers(0, 64, (4, 16)), jnp.int32)
    loss = model_transformer.loss_fn(spec, theta, tokens)
    assert abs(float(loss) - np.log(64)) < 0.5


def test_transformer_causality():
    # Changing a future token must not affect earlier logits.
    spec = model_transformer.TransformerSpec(vocab=16, seq=8, d=16, heads=2, layers=1, ff=32)
    theta = spec.init(jax.random.PRNGKey(2))
    t1 = jnp.asarray(rng(2).integers(0, 16, (1, 8)), jnp.int32)
    t2 = t1.at[0, 7].set((t1[0, 7] + 1) % 16)
    l1 = model_transformer.forward(spec, theta, t1)
    l2 = model_transformer.forward(spec, theta, t2)
    assert_allclose(np.asarray(l1[0, :7]), np.asarray(l2[0, :7]), atol=1e-5)


def test_transformer_grad_entry_learns():
    spec = model_transformer.TransformerSpec(vocab=8, seq=8, d=16, heads=2, layers=1, ff=32)
    entry = jax.jit(model_transformer.make_grad_entry(spec))
    theta = spec.init(jax.random.PRNGKey(3))
    # A trivially predictable stream: ascending tokens mod 8.
    tokens = jnp.asarray([[(i + s) % 8 for i in range(8)] for s in range(4)], jnp.float32)
    _, loss0 = entry(theta, tokens)
    for _ in range(40):
        g, _ = entry(theta, tokens)
        theta = theta - 0.5 * g
    _, loss1 = entry(theta, tokens)
    assert float(loss1) < 0.5 * float(loss0), f"{float(loss0)} -> {float(loss1)}"
