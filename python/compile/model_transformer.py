"""L2 transformer language model — the end-to-end validation driver.

A compact pre-LN decoder-only transformer (learned positional embeddings,
tied unembedding) whose entire parameter set travels as one flat f32
vector so the rust coordinator can sparsify it like any other gradient.
The e2e example trains it with distributed REGTOP-k on the synthetic
Markov corpus and logs the loss curve (EXPERIMENTS.md §E2E).

Flat layout (per layer, then globals):
  for each layer l:  ln1_scale ln1_bias | Wqkv (d, 3d) | bqkv | Wo (d, d) |
                     bo | ln2_scale ln2_bias | Wff1 (d, f) | bff1 |
                     Wff2 (f, d) | bff2
  then: tok_embed (V, d) | pos_embed (T, d) | lnf_scale lnf_bias
"""

import jax
import jax.numpy as jnp


class TransformerSpec:
    def __init__(self, vocab=256, seq=64, d=128, heads=4, layers=2, ff=512):
        assert d % heads == 0
        self.vocab = vocab
        self.seq = seq
        self.d = d
        self.heads = heads
        self.layers = layers
        self.ff = ff

    def layer_dims(self):
        d, f = self.d, self.ff
        return 2 * d + d * 3 * d + 3 * d + d * d + d + 2 * d + d * f + f + f * d + d

    def dims(self):
        return (
            self.layers * self.layer_dims()
            + self.vocab * self.d
            + self.seq * self.d
            + 2 * self.d
        )

    def init(self, key):
        d, f = self.d, self.ff
        parts = []
        for l in range(self.layers):
            ks = jax.random.split(jax.random.fold_in(key, l), 4)
            parts += [
                jnp.ones(d), jnp.zeros(d),                                  # ln1
                (jax.random.normal(ks[0], (d, 3 * d)) * d ** -0.5).reshape(-1),
                jnp.zeros(3 * d),
                (jax.random.normal(ks[1], (d, d)) * d ** -0.5).reshape(-1),
                jnp.zeros(d),
                jnp.ones(d), jnp.zeros(d),                                  # ln2
                (jax.random.normal(ks[2], (d, f)) * d ** -0.5).reshape(-1),
                jnp.zeros(f),
                (jax.random.normal(ks[3], (f, d)) * f ** -0.5).reshape(-1),
                jnp.zeros(d),
            ]
        ke, kp = jax.random.split(jax.random.fold_in(key, 999))
        parts += [
            (jax.random.normal(ke, (self.vocab, d)) * 0.02).reshape(-1),
            (jax.random.normal(kp, (self.seq, d)) * 0.02).reshape(-1),
            jnp.ones(d), jnp.zeros(d),                                      # final ln
        ]
        return jnp.concatenate([p.astype(jnp.float32) for p in parts])

    def unflatten(self, theta):
        d, f = self.d, self.ff
        o = 0

        def take(n, shape=None):
            nonlocal o
            v = theta[o : o + n]
            o += n
            return v.reshape(shape) if shape else v

        layers = []
        for _ in range(self.layers):
            layers.append(
                dict(
                    ln1_s=take(d), ln1_b=take(d),
                    wqkv=take(d * 3 * d, (d, 3 * d)), bqkv=take(3 * d),
                    wo=take(d * d, (d, d)), bo=take(d),
                    ln2_s=take(d), ln2_b=take(d),
                    w1=take(d * f, (d, f)), b1=take(f),
                    w2=take(f * d, (f, d)), b2=take(d),
                )
            )
        tok = take(self.vocab * d, (self.vocab, d))
        pos = take(self.seq * d, (self.seq, d))
        lnf_s, lnf_b = take(d), take(d)
        return layers, tok, pos, lnf_s, lnf_b


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def forward(spec, theta, tokens):
    """tokens: int32 (B, T) -> logits (B, T, V)."""
    layers, tok, pos, lnf_s, lnf_b = spec.unflatten(theta)
    b, t = tokens.shape
    h = tok[tokens] + pos[None, :t, :]
    causal = jnp.tril(jnp.ones((t, t), bool))
    nh, hd = spec.heads, spec.d // spec.heads
    for p in layers:
        x = _layernorm(h, p["ln1_s"], p["ln1_b"])
        qkv = x @ p["wqkv"] + p["bqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
        att = (q @ k.transpose(0, 1, 3, 2)) * hd ** -0.5
        att = jnp.where(causal[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, spec.d)
        h = h + out @ p["wo"] + p["bo"]
        x = _layernorm(h, p["ln2_s"], p["ln2_b"])
        h = h + jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
    h = _layernorm(h, lnf_s, lnf_b)
    return h @ tok.T  # tied unembedding


def loss_fn(spec, theta, tokens):
    """Next-token cross entropy (nats)."""
    logits = forward(spec, theta, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    targets = tokens[:, 1:]
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def make_grad_entry(spec):
    """(theta[P], tokens_f32[B,T]) -> (grad[P], loss[]).

    Tokens travel as f32 (the runtime's uniform literal type) and are cast
    to int32 inside the computation.
    """

    def entry(theta, tokens_f32):
        tokens = tokens_f32.astype(jnp.int32)
        loss, grad = jax.value_and_grad(lambda t: loss_fn(spec, t, tokens))(theta)
        return grad, loss

    return entry


def make_eval_entry(spec):
    def entry(theta, tokens_f32):
        tokens = tokens_f32.astype(jnp.int32)
        return (loss_fn(spec, theta, tokens),)

    return entry
