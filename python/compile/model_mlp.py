"""L2 MLP classifier — the JAX twin of the native rust model.

Parameter layout matches `rust/src/models/mlp.rs` exactly:
    [W1 (input x hidden) | b1 (hidden) | W2 (hidden x classes) | b2]
so the rust integration test can feed the same flat theta to both paths
and assert the gradients agree — the strongest cross-language check in
the repository.
"""

import jax
import jax.numpy as jnp


def dims(input_, hidden, classes):
    """Total flat parameter count."""
    return input_ * hidden + hidden + hidden * classes + classes


def unflatten(theta, input_, hidden, classes):
    w1_end = input_ * hidden
    b1_end = w1_end + hidden
    w2_end = b1_end + hidden * classes
    w1 = theta[:w1_end].reshape(input_, hidden)
    b1 = theta[w1_end:b1_end]
    w2 = theta[b1_end:w2_end].reshape(hidden, classes)
    b2 = theta[w2_end:]
    return w1, b1, w2, b2


def forward(theta, x, input_, hidden, classes):
    """Batched logits: relu(x W1 + b1) W2 + b2."""
    w1, b1, w2, b2 = unflatten(theta, input_, hidden, classes)
    h = jax.nn.relu(x @ w1 + b1)
    return h @ w2 + b2


def loss_acc(theta, x, y_onehot, input_, hidden, classes):
    logits = forward(theta, x, input_, hidden, classes)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))
    acc = jnp.mean(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y_onehot, axis=-1)).astype(jnp.float32)
    )
    return loss, acc


def make_grad_entry(input_, hidden, classes):
    """(theta, x[B,input], y_onehot[B,classes]) -> (grad, loss, acc)."""

    def entry(theta, x, y_onehot):
        def loss_fn(t):
            loss, acc = loss_acc(t, x, y_onehot, input_, hidden, classes)
            return loss, acc

        (loss, acc), grad = jax.value_and_grad(loss_fn, has_aux=True)(theta)
        return grad, loss, acc

    return entry


def make_eval_entry(input_, hidden, classes):
    """(theta, x, y_onehot) -> (loss, acc)."""

    def entry(theta, x, y_onehot):
        return loss_acc(theta, x, y_onehot, input_, hidden, classes)

    return entry
