"""L2 JAX models (build-time only): the computations AOT-lowered to HLO.

Each exported entry is a pure function over f32 arrays whose first input
is the flat parameter vector theta and whose first output is the gradient
of the local loss wrt theta — the contract `rust/src/runtime/hlo_grad.rs`
expects. The linear-regression entry routes through the L1 Pallas kernels
(same HLO module after lowering); heavier models live in model_mlp.py /
model_cnn.py / model_transformer.py.
"""

import jax
import jax.numpy as jnp

from .kernels import linreg_grad as linreg_kernels
from .kernels import regtopk_score as score_kernel


def linreg_grad_entry(theta, x, y):
    """(theta[J], x[D,J], y[D]) -> (grad[J], loss[]) via Pallas kernels."""
    g, loss = linreg_kernels.linreg_grad(theta, x, y)
    return g, loss


def toy_logistic_grad_entry(theta, x):
    """The §1.3 toy worker: loss log(1+exp(-<theta; x>)), label fixed to 1.

    (theta[2], x[2]) -> (grad[2], loss[]).
    """
    z = jnp.dot(theta, x)
    # Stable log(1 + exp(-z)) and its gradient -(1 - sigmoid(z)) x.
    loss = jnp.logaddexp(0.0, -z)
    coeff = -(1.0 - jax.nn.sigmoid(z))
    return coeff * x, loss


def regtopk_score_entry(a, a_prev, g_prev, mask_prev, scalars):
    """(a[J], a_prev[J], g_prev[J], mask_prev[J], [omega, mu]) -> scores[J].

    The worker-side score pass as a standalone artifact (used by the
    score-backend ablation bench in rust).
    """
    return (score_kernel.regtopk_score(a, a_prev, g_prev, mask_prev, scalars),)
