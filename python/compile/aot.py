"""AOT compile pipeline: lower every L2 entry to HLO text + manifest.

Run once via `make artifacts` (python -m compile.aot --out ../artifacts).
The rust runtime consumes artifacts/manifest.json and the *.hlo.txt files;
python never runs at training time.

Interchange is HLO TEXT, not serialized HloModuleProto: the image's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids); the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, model_cnn, model_mlp, model_transformer

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(fn, example_args):
    """Lower a jax function to HLO text with return_tuple=True."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Entry registry. Shapes are the experiment defaults; DESIGN.md §4 maps each
# entry to the figure/table it serves.
# ---------------------------------------------------------------------------

LINREG_D, LINREG_J = 500, 100
SCORE_J = 65536
MLP = dict(input=192, hidden=32, classes=10, batch=16)
CNN = model_cnn.CnnSpec(side=16, classes=10, c1=16, c2=32)
CNN_BATCH, CNN_WORKERS = 32, 8
TRANSFORMER = model_transformer.TransformerSpec(
    vocab=256, seq=64, d=128, heads=4, layers=2, ff=512
)
TF_BATCH, TF_WORKERS = 8, 4


def entries():
    """(name, fn, example_args, input_names, output_names, meta, init_fn)."""
    mlp_grad = model_mlp.make_grad_entry(MLP["input"], MLP["hidden"], MLP["classes"])
    mlp_eval = model_mlp.make_eval_entry(MLP["input"], MLP["hidden"], MLP["classes"])
    mlp_dim = model_mlp.dims(MLP["input"], MLP["hidden"], MLP["classes"])
    cnn_grad = model_cnn.make_grad_entry(CNN)
    cnn_eval = model_cnn.make_eval_entry(CNN)
    tf_grad = model_transformer.make_grad_entry(TRANSFORMER)
    tf_eval = model_transformer.make_eval_entry(TRANSFORMER)
    return [
        (
            "linreg_grad",
            model.linreg_grad_entry,
            (spec(LINREG_J), spec(LINREG_D, LINREG_J), spec(LINREG_D)),
            ["theta", "x", "y"],
            ["grad", "loss"],
            {"dim": LINREG_J, "points": LINREG_D},
            None,
        ),
        (
            "toy_logistic_grad",
            model.toy_logistic_grad_entry,
            (spec(2), spec(2)),
            ["theta", "x"],
            ["grad", "loss"],
            {"dim": 2},
            None,
        ),
        (
            "regtopk_score",
            model.regtopk_score_entry,
            (spec(SCORE_J), spec(SCORE_J), spec(SCORE_J), spec(SCORE_J), spec(2)),
            ["a", "a_prev", "g_prev", "mask_prev", "scalars"],
            ["scores"],
            {"dim": SCORE_J},
            None,
        ),
        (
            "mlp_grad",
            mlp_grad,
            (spec(mlp_dim), spec(MLP["batch"], MLP["input"]), spec(MLP["batch"], MLP["classes"])),
            ["theta", "x", "y_onehot"],
            ["grad", "loss", "acc"],
            {**MLP, "dim": mlp_dim},
            None,
        ),
        (
            "mlp_eval",
            mlp_eval,
            (spec(mlp_dim), spec(MLP["batch"], MLP["input"]), spec(MLP["batch"], MLP["classes"])),
            ["theta", "x", "y_onehot"],
            ["loss", "acc"],
            {**MLP, "dim": mlp_dim},
            None,
        ),
        (
            "cnn_grad",
            cnn_grad,
            (
                spec(CNN.dims()),
                spec(CNN_BATCH, 3 * CNN.side * CNN.side),
                spec(CNN_BATCH, CNN.classes),
            ),
            ["theta", "x", "y_onehot"],
            ["grad", "loss", "acc"],
            {
                "dim": CNN.dims(),
                "side": CNN.side,
                "classes": CNN.classes,
                "batch": CNN_BATCH,
                "workers": CNN_WORKERS,
                "has_init": 1,
            },
            lambda: CNN.init(jax.random.PRNGKey(0)),
        ),
        (
            "cnn_eval",
            cnn_eval,
            (
                spec(CNN.dims()),
                spec(CNN_BATCH, 3 * CNN.side * CNN.side),
                spec(CNN_BATCH, CNN.classes),
            ),
            ["theta", "x", "y_onehot"],
            ["loss", "acc"],
            {"dim": CNN.dims(), "side": CNN.side, "classes": CNN.classes, "batch": CNN_BATCH},
            None,
        ),
        (
            "transformer_grad",
            tf_grad,
            (spec(TRANSFORMER.dims()), spec(TF_BATCH, TRANSFORMER.seq)),
            ["theta", "tokens"],
            ["grad", "loss"],
            {
                "dim": TRANSFORMER.dims(),
                "vocab": TRANSFORMER.vocab,
                "seq": TRANSFORMER.seq,
                "batch": TF_BATCH,
                "workers": TF_WORKERS,
                "d_model": TRANSFORMER.d,
                "layers": TRANSFORMER.layers,
                "has_init": 1,
            },
            lambda: TRANSFORMER.init(jax.random.PRNGKey(1)),
        ),
        (
            "transformer_eval",
            tf_eval,
            (spec(TRANSFORMER.dims()), spec(TF_BATCH, TRANSFORMER.seq)),
            ["theta", "tokens"],
            ["loss"],
            {"dim": TRANSFORMER.dims(), "vocab": TRANSFORMER.vocab, "seq": TRANSFORMER.seq,
             "batch": TF_BATCH},
            None,
        ),
    ]


def tensor_specs(names, args):
    return [
        {"name": n, "shape": list(a.shape), "dtype": "f32"}
        for n, a in zip(names, args)
    ]


def output_specs(fn, args, names):
    out = jax.eval_shape(fn, *args)
    if not isinstance(out, (tuple, list)):
        out = (out,)
    return [
        {"name": n, "shape": list(o.shape), "dtype": "f32"}
        for n, o in zip(names, out)
    ]


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument("--only", default=None, help="comma-separated entry names")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None
    manifest = {"version": 1, "entries": []}
    for name, fn, example, in_names, out_names, meta, init_fn in entries():
        if only and name not in only:
            continue
        print(f"lowering {name} ...", flush=True)
        text = to_hlo_text(fn, example)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "inputs": tensor_specs(in_names, example),
            "outputs": output_specs(fn, example, out_names),
            "meta": meta,
        }
        if init_fn is not None:
            init = init_fn()
            init_name = f"{name}.init.f32"
            with open(os.path.join(args.out, init_name), "wb") as f:
                f.write(np.asarray(init, np.float32).tobytes())
        manifest["entries"].append(entry)
        print(f"  {fname}: {len(text)} chars")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}/manifest.json ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
