"""L2 CNN classifier — the ResNet-18/CIFAR-10 stand-in for Fig. 6.

Architecture (NCHW): conv3x3(3->c1) + relu + maxpool2 -> conv3x3(c1->c2)
+ relu + maxpool2 -> flatten -> dense(classes). Parameters travel as one
flat f32 vector (layout below) so the rust coordinator can sparsify them
uniformly, exactly as it does for every other model.

Layout: [conv1 (c1,3,3,3) | b1 (c1) | conv2 (c2,c1,3,3) | b2 (c2)
         | dense W (feat, classes) | dense b (classes)]
"""

import jax
import jax.numpy as jnp


class CnnSpec:
    def __init__(self, side=16, classes=10, c1=16, c2=32):
        self.side = side
        self.classes = classes
        self.c1 = c1
        self.c2 = c2
        # Two stride-2 pools.
        self.feat_side = side // 4
        self.feat = self.feat_side * self.feat_side * c2

    def dims(self):
        return (
            self.c1 * 3 * 3 * 3
            + self.c1
            + self.c2 * self.c1 * 3 * 3
            + self.c2
            + self.feat * self.classes
            + self.classes
        )

    def unflatten(self, theta):
        s = self
        o = 0
        k1 = theta[o : o + s.c1 * 27].reshape(s.c1, 3, 3, 3)
        o += s.c1 * 27
        b1 = theta[o : o + s.c1]
        o += s.c1
        k2 = theta[o : o + s.c2 * s.c1 * 9].reshape(s.c2, s.c1, 3, 3)
        o += s.c2 * s.c1 * 9
        b2 = theta[o : o + s.c2]
        o += s.c2
        w = theta[o : o + s.feat * s.classes].reshape(s.feat, s.classes)
        o += s.feat * s.classes
        b = theta[o : o + s.classes]
        return k1, b1, k2, b2, w, b

    def init(self, key):
        """He-initialized flat parameter vector."""
        s = self
        ks = jax.random.split(key, 3)
        k1 = jax.random.normal(ks[0], (s.c1, 3, 3, 3)) * (2.0 / 27) ** 0.5
        k2 = jax.random.normal(ks[1], (s.c2, s.c1, 3, 3)) * (2.0 / (s.c1 * 9)) ** 0.5
        w = jax.random.normal(ks[2], (s.feat, s.classes)) * (2.0 / s.feat) ** 0.5
        return jnp.concatenate(
            [
                k1.reshape(-1),
                jnp.zeros(s.c1),
                k2.reshape(-1),
                jnp.zeros(s.c2),
                w.reshape(-1),
                jnp.zeros(s.classes),
            ]
        ).astype(jnp.float32)


def _conv(x, k, b):
    """3x3 same conv, NCHW/OIHW."""
    out = jax.lax.conv_general_dilated(
        x, k, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out + b[None, :, None, None]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def forward(spec, theta, x_flat):
    """x_flat: (B, 3*side*side) CHW-flattened images -> logits (B, classes)."""
    b = x_flat.shape[0]
    x = x_flat.reshape(b, 3, spec.side, spec.side)
    k1, b1, k2, b2, w, bias = spec.unflatten(theta)
    x = _maxpool2(jax.nn.relu(_conv(x, k1, b1)))
    x = _maxpool2(jax.nn.relu(_conv(x, k2, b2)))
    x = x.reshape(b, -1)
    return x @ w + bias


def loss_acc(spec, theta, x_flat, y_onehot):
    logits = forward(spec, theta, x_flat)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))
    acc = jnp.mean(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y_onehot, axis=-1)).astype(jnp.float32)
    )
    return loss, acc


def make_grad_entry(spec):
    """(theta, x[B, 3*side^2], y_onehot[B, classes]) -> (grad, loss, acc)."""

    def entry(theta, x_flat, y_onehot):
        def loss_fn(t):
            return loss_acc(spec, t, x_flat, y_onehot)

        (loss, acc), grad = jax.value_and_grad(loss_fn, has_aux=True)(theta)
        return grad, loss, acc

    return entry


def make_eval_entry(spec):
    def entry(theta, x_flat, y_onehot):
        return loss_acc(spec, theta, x_flat, y_onehot)

    return entry
