"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here;
pytest sweeps shapes/parameters (hypothesis) and asserts allclose.
"""

import jax.numpy as jnp

DELTA_GUARD = 1e-30


def regtopk_score_ref(a, a_prev, g_prev, mask_prev, omega, mu):
    """REGTOP-k selection scores (Algorithm 2, lines 8-9).

    score_j = |a_j| * tanh(|1 + delta_j| / mu)   for mask_prev_j = 1
    score_j = |a_j| * C (C = 1)                  otherwise
    delta_j = (g_prev_j - omega * a_prev_j) / (omega * a_prev_j)

    Delta is normalized by the *previous* accumulated gradient — see the
    reproduction note in DESIGN.md §2 / rust/src/sparsify/regtopk.rs.
    mu = 0 is the TOP-k limit (u = 1).
    """
    a = jnp.asarray(a, jnp.float32)
    denom = omega * jnp.asarray(a_prev, jnp.float32)
    safe = jnp.abs(denom) > DELTA_GUARD
    delta = jnp.where(safe, (jnp.asarray(g_prev, jnp.float32) - denom)
                      / jnp.where(safe, denom, 1.0), 0.0)
    reg = jnp.where(
        mu > 0.0,
        jnp.tanh(jnp.abs(1.0 + delta) / jnp.where(mu > 0.0, mu, 1.0)),
        1.0,
    )
    u = jnp.where(jnp.asarray(mask_prev, jnp.float32) > 0.5,
                  jnp.where(safe, reg, 1.0), 1.0)
    return jnp.abs(a) * u


def linreg_grad_ref(theta, x, y):
    """Full-batch least-squares gradient: 2/D * X^T (X theta - y)."""
    theta = jnp.asarray(theta, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    resid = x @ theta - y
    d = x.shape[0]
    return (2.0 / d) * (x.T @ resid)


def linreg_loss_ref(theta, x, y):
    """RSS loss (eq. 48): ||X theta - y||^2 / D."""
    resid = jnp.asarray(x, jnp.float32) @ jnp.asarray(theta, jnp.float32) - y
    return jnp.mean(resid * resid) * resid.shape[0] / x.shape[0]
