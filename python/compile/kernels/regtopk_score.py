"""L1 Pallas kernel: fused REGTOP-k score computation.

One sweep over J computes, per entry, the posterior distortion, the tanh
regularizer and the final selection score — no intermediate arrays
materialized in HBM. This is the per-worker per-iteration hot spot of the
sparsifier itself (the gradient computation is the other hot spot, see
linreg_grad.py).

TPU mapping (DESIGN.md §5): a pure VPU elementwise kernel. Inputs are
tiled into (BLOCK,)-sized VMEM blocks via BlockSpec; with BLOCK = 1024 the
working set is 4 input blocks + 1 output block * 4 B = 20 KiB, far under
the ~16 MiB VMEM budget, so the kernel is memory-bandwidth-bound at one
HBM pass per operand — the roofline for this op.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DELTA_GUARD = 1e-30
BLOCK = 1024


def _score_kernel(a_ref, a_prev_ref, g_prev_ref, mask_ref, scal_ref, out_ref):
    """scal_ref holds [omega, mu] broadcast to every grid step."""
    a = a_ref[...]
    a_prev = a_prev_ref[...]
    g_prev = g_prev_ref[...]
    mask = mask_ref[...]
    omega = scal_ref[0]
    mu = scal_ref[1]
    denom = omega * a_prev
    safe = jnp.abs(denom) > DELTA_GUARD
    delta = jnp.where(safe, (g_prev - denom) / jnp.where(safe, denom, 1.0), 0.0)
    # mu = 0 -> u = 1 (TOP-k limit); guard the division.
    mu_safe = jnp.where(mu > 0.0, mu, 1.0)
    reg = jnp.where(mu > 0.0, jnp.tanh(jnp.abs(1.0 + delta) / mu_safe), 1.0)
    u = jnp.where((mask > 0.5) & safe, reg, 1.0)
    out_ref[...] = jnp.abs(a) * u


@functools.partial(jax.jit, static_argnames=())
def regtopk_score(a, a_prev, g_prev, mask_prev, scalars):
    """Compute REGTOP-k scores for a flat gradient vector.

    Args:
      a, a_prev, g_prev, mask_prev: f32[J] (mask is 0.0/1.0)
      scalars: f32[2] = [omega, mu]

    Returns: f32[J] selection scores.
    """
    j = a.shape[0]
    padded = (j + BLOCK - 1) // BLOCK * BLOCK
    pad = padded - j

    def pad1(v):
        # Pad a_prev with ones (not zeros) so the padded lane's delta math
        # stays in the "safe" branch; values are sliced away regardless.
        return jnp.pad(v, (0, pad), constant_values=1.0)

    a_p = jnp.pad(a, (0, pad))
    out = pl.pallas_call(
        _score_kernel,
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        grid=(padded // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),  # scalars broadcast
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,
    )(a_p, pad1(a_prev), pad1(g_prev), jnp.pad(mask_prev, (0, pad)), scalars)
    return out[:j]
