"""L1 Pallas kernels: tiled least-squares gradient 2/D * X^T (X theta - y).

Two kernels chained by the L2 wrapper:
  1. residual: r = X theta - y, tiled over rows of X
  2. grad:     g = 2/D * X^T r,  tiled over columns of X

TPU mapping (DESIGN.md §5): each grid step of the residual kernel loads a
(ROWS x J) block of X into VMEM and contracts it with theta on the MXU;
the grad kernel loads (D x COLS) column panels. For the paper's
D=500, J=100 the panels are 500*128*4 B = 256 KiB — VMEM-resident with
double-buffering room to spare. BlockSpec expresses the HBM->VMEM
schedule a CUDA implementation would express with threadblock tiling.

interpret=True: see regtopk_score.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128
COL_BLOCK = 64


def _residual_kernel(x_ref, theta_ref, y_ref, out_ref):
    # (ROWS, J) @ (J,) - (ROWS,)
    out_ref[...] = x_ref[...] @ theta_ref[...] - y_ref[...]


def _grad_kernel(x_ref, r_ref, scale_ref, out_ref):
    # (D, COLS)^T @ (D,) * 2/D
    out_ref[...] = (x_ref[...].T @ r_ref[...]) * scale_ref[0]


def residual(x, theta, y):
    """r = X theta - y with row-tiled Pallas matvec."""
    d, j = x.shape
    padded = (d + ROW_BLOCK - 1) // ROW_BLOCK * ROW_BLOCK
    pad = padded - d
    x_p = jnp.pad(x, ((0, pad), (0, 0)))
    y_p = jnp.pad(y, (0, pad))
    out = pl.pallas_call(
        _residual_kernel,
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        grid=(padded // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, j), lambda i: (i, 0)),
            pl.BlockSpec((j,), lambda i: (0,)),
            pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
        interpret=True,
    )(x_p, theta, y_p)
    return out[:d]


def grad_from_residual(x, r):
    """g = 2/D * X^T r with column-tiled Pallas matvec."""
    d, j = x.shape
    padded = (j + COL_BLOCK - 1) // COL_BLOCK * COL_BLOCK
    pad = padded - j
    x_p = jnp.pad(x, ((0, 0), (0, pad)))
    scale = jnp.array([2.0 / d], jnp.float32)
    out = pl.pallas_call(
        _grad_kernel,
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        grid=(padded // COL_BLOCK,),
        in_specs=[
            pl.BlockSpec((d, COL_BLOCK), lambda i: (0, i)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((COL_BLOCK,), lambda i: (i,)),
        interpret=True,
    )(x_p, r, scale)
    return out[:j]


@jax.jit
def linreg_grad(theta, x, y):
    """Full-batch least-squares gradient through the Pallas kernels.

    Returns (grad f32[J], loss f32[]).
    """
    r = residual(x, theta, y)
    g = grad_from_residual(x, r)
    loss = jnp.mean(r * r)
    return g, loss
