//! Loom model-checking harness for the two hand-rolled synchronization
//! protocols in the main crate: the SPSC ring channel
//! (`coordinator::ring`) and the scoped GEMM pool's countdown latch
//! (`tensor::pool`). Loom exhausts every thread interleaving of each
//! model, so the properties below hold for *all* schedules, not just the
//! ones a sleep-based unit test happens to provoke.
//!
//! The production sources are included verbatim via `#[path]` — there is
//! no copy to drift out of date. Under `--cfg loom` those files swap
//! `std::sync`/`std::thread` for loom's versions and compile out the
//! process-global machinery (sysfs census, `OnceLock` pool, thread-local
//! budgets), which a model checker cannot host.
//!
//! Run with:
//!
//! ```sh
//! cd loom && RUSTFLAGS="--cfg loom" cargo test --release
//! ```
//!
//! Without `--cfg loom` this crate still builds against std and runs the
//! included files' ordinary unit tests, so a plain `cargo test` here is
//! harmless (just redundant with the root crate's).

#[path = "../../rust/src/coordinator/ring.rs"]
pub mod ring;

#[path = "../../rust/src/tensor/pool.rs"]
pub mod pool;

/// No-op stand-in for the main crate's `obs` flight recorder: the included
/// files call `crate::obs::span(..)` on their hot paths, and the models
/// only need those calls to compile, not to record. (Observability is
/// deliberately out of model scope — a disarmed span has no
/// synchronization, so it cannot change the interleavings being checked.)
pub mod obs {
    #[derive(Clone, Copy, Debug)]
    pub enum SpanKind {
        Round,
        GemmPack,
        GemmKernel,
        GemmPanelSource,
        PoolFanout,
        Im2colGather,
        SparsifySelect,
        SparsifyCompress,
        MergeShard,
        RingSend,
        RingSendBlocked,
        RingRecv,
        LaneRound,
        SnapshotIo,
        CheckpointIo,
    }

    #[must_use]
    pub struct Span;

    pub fn span(_kind: SpanKind) -> Span {
        Span
    }

    pub fn span_arg(_kind: SpanKind, _arg: u32) -> Span {
        Span
    }
}

#[cfg(all(test, loom))]
mod models {
    use crate::pool::ScopedPool;
    use crate::ring::{ring_channel, RecvError};
    use loom::thread;

    /// FIFO delivery through a capacity-1 ring (every send after the first
    /// blocks on a full ring), then the disconnect drain: messages buffered
    /// before the sender dropped are still delivered, and only an empty,
    /// disconnected ring errors.
    #[test]
    fn ring_fifo_then_drain_then_error() {
        loom::model(|| {
            let (tx, rx) = ring_channel::<u32>(1);
            let producer = thread::spawn(move || {
                tx.send(1).unwrap();
                tx.send(2).unwrap();
                // tx drops here: disconnect races with the final recvs.
            });
            assert_eq!(rx.recv(), Ok(1));
            assert_eq!(rx.recv(), Ok(2));
            assert_eq!(rx.recv(), Err(RecvError));
            producer.join().unwrap();
        });
    }

    /// A sender parked on a full ring must wake and fail — payload handed
    /// back, never a hang — when the receiver drops. This is the executor's
    /// worker-death detection path.
    #[test]
    fn ring_blocked_sender_wakes_and_fails_on_receiver_drop() {
        loom::model(|| {
            let (tx, rx) = ring_channel::<u32>(1);
            tx.send(1).unwrap();
            let producer = thread::spawn(move || tx.send(2));
            drop(rx);
            let r = producer.join().unwrap();
            let err = r.expect_err("send to a dropped receiver must fail");
            assert_eq!(err.0, 2, "the unsent payload must be handed back");
        });
    }

    /// recv racing a concurrent send must always observe the message (the
    /// not_empty signal cannot be lost between the occupancy check and the
    /// condvar wait).
    #[test]
    fn ring_recv_never_misses_a_concurrent_send() {
        loom::model(|| {
            let (tx, rx) = ring_channel::<u32>(2);
            let producer = thread::spawn(move || tx.send(7).unwrap());
            assert_eq!(rx.recv(), Ok(7));
            producer.join().unwrap();
        });
    }

    /// The latch protocol behind `ScopedPool::scope`: the call must not
    /// return before the offloaded job has fully run, in every schedule.
    /// That blocking wait is the exact soundness argument for the
    /// lifetime-erasing transmute inside `scope` — the borrowed task can
    /// never outlive the call — so exhausting the interleavings here checks
    /// the `// SAFETY:` claim itself, not just liveness.
    #[test]
    fn pool_scope_blocks_until_offloaded_write_lands() {
        loom::model(|| {
            let pool = ScopedPool::new(1);
            let mut out = [0u32; 2];
            {
                let (a, b) = out.split_at_mut(1);
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                    Box::new(move || a[0] = 1), // offloaded to the worker
                    Box::new(move || b[0] = 2), // runs inline as `last`
                ];
                pool.scope(tasks);
            }
            assert_eq!(out, [1, 2], "scope returned before the pooled job ran");
        });
    }

    /// Back-to-back scopes on one pool (fresh latch per scope, no stale
    /// wakeups crossing between them), then the shutdown handshake when the
    /// pool drops at the end of the model.
    #[test]
    fn pool_scopes_are_reusable_and_shutdown_terminates() {
        loom::model(|| {
            let pool = ScopedPool::new(1);
            for round in 1..=2u32 {
                let mut out = [0u32; 2];
                {
                    let (a, b) = out.split_at_mut(1);
                    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                        Box::new(move || a[0] = round),
                        Box::new(move || b[0] = round),
                    ];
                    pool.scope(tasks);
                }
                assert_eq!(out, [round, round]);
            }
            // `pool` drops here: worker must observe the shutdown flag and
            // exit its queue loop (join would hang forever otherwise).
        });
    }
}
